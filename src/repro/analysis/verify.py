"""Rule engine over the ReduceSchedule IR — static soundness proofs.

Every rule re-derives an invariant the rest of the stack *relies on*
but only ever checked by executing on small meshes:

``SV000``  well-formedness: unique positive axes, known placement,
           parseable wire dtype, parseable strategy names, unique
           bucket indices.
``SV001``  byte conservation: each bucket's stage list must match a
           fresh :func:`repro.core.schedule.decompose` of its strategy
           structurally (op/algorithm/axis/sizes/bytes), and the bucket
           total must equal the ``reducers.wire_bytes`` /
           ``hierarchical_wire_bytes`` closed forms.
``SV002``  stage legality: reduce_scatter/all_gather pair like
           parentheses per axis (exactly the stack discipline
           ``reducers.execute_stages`` enforces at run time) and the
           mesh axes are each covered exactly once per level.
``SV003``  leaf partition: bucket leaf indices tile the gradient tree
           with no overlap and no gap.
``SV004``  readiness: ranks are a permutation, and monotone in
           reverse-layer order (descending min leaf index — the
           wait-free-backprop issue order of ``overlap
           .readiness_order``).
``SV005``  no fused bucket straddles a selector crossover point
           (replays ``fusion.build_plan``'s ``_crosses`` predicate
           post hoc on the committed layout).
``SV006``  wire-dtype tolerance: a reduced-precision wire dtype must
           carry a derivable summation-error bound
           (:func:`wire_tolerance` — the ``(log2 p + 1)·eps`` model
           tests/test_wire_dtype.py validates empirically).
``SV007``  fingerprint latency-insensitivity: perturbing every
           predicted latency must not move ``fingerprint()`` (re-plan
           determinism — cost-model constant changes may never fault
           the plan cache or the trajectory diff).
``SV008``  wire-codec soundness: a codec'd stage must carry a codec
           with a derivable per-hop error bound (:data:`CODEC_WIRE`),
           ride an algorithm whose hops are explicit ppermutes
           (ring_rsa/rhd_rsa — psum's hops are vendor-internal and
           cannot re-quantize), and charge exactly the ENCODED wire
           bytes plus one 4-byte f32 scale scalar per hop for scaled
           codecs.  The byte arithmetic is restated here from first
           principles, independent of ``core/codec.py``.
``SV009``  fused-hop soundness: the ``fused_hop`` flag may only ride
           stages with an accumulating hop or terminal reduce
           (:data:`FUSED_HOP_OPS`, restated independently of
           ``core/reducers.py``), and clearing every flag
           (``schedule.with_fused_hops(sched, False)``) must leave the
           derived tolerances and all stage byte accounting untouched —
           fusion is an execution route, not a different reduction.

All rules run on detached schedules (``plan=None``); the rules that
need the leaf layout (SV003 leaf-gap, SV004 monotonicity, SV005)
degrade to the checks the available metadata supports.  This is what
lets a 512-device three-axis schedule — which the legacy-jax executor
refuses outright — be verified without running it.
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.core import reducers
from repro.core import schedule as schedule_mod

from . import ERROR, Diagnostic

# rule_id -> one-line contract (the registry DESIGN.md §3.9 documents)
RULES = {
    "SV000": "schedule is well-formed (axes, placement, dtype, names)",
    "SV001": "stage wire bytes equal the reducers closed forms",
    "SV002": "RS/AG stages pair per axis; axes covered once per level",
    "SV003": "bucket leaf indices partition the gradient tree",
    "SV004": "readiness ranks are monotone in reverse-layer order",
    "SV005": "no fused bucket straddles a selector crossover point",
    "SV006": "reduced-precision wire dtype has a derivable tolerance",
    "SV007": "fingerprint is insensitive to predicted latencies",
    "SV008": "codec'd stages have derivable bounds and encoded bytes",
    "SV009": "fused hops ride accumulating stages; bounds/bytes invariant",
}

# Unit roundoff of the dtypes we allow on the wire: the summation-error
# model |err| <= (log2 p + 1)·eps·|x| (sequential-halving depth of a
# p-way tree reduction) is validated by tests/test_wire_dtype.py for
# bf16; dtypes outside this table have no derivable bound and SV006
# refuses them.
WIRE_EPS = {
    "bfloat16": 2.0 ** -8,
    "float16": 2.0 ** -11,
    "float32": 2.0 ** -24,
    "float64": 2.0 ** -53,
}


def wire_tolerance(sched) -> float | None:
    """Relative summation-error bound of one reduction over the
    schedule's full device product, or None when the wire dtype has no
    entry in :data:`WIRE_EPS` (no derivable bound)."""
    eps = WIRE_EPS.get(str(sched.wire_dtype))
    if eps is None:
        return None
    p = 1
    for s in sched.axis_sizes:
        p *= int(s)
    return (math.log2(max(p, 1)) + 1.0) * eps


# Wire-codec identity table for SV008: codec name -> (payload itemsize
# in bytes/element, carries a per-bucket absmax scale scalar).  This
# RESTATES core/codec.py rather than importing its registry — the
# verifier's byte arithmetic must stay independent of the module it
# audits, so a codec-module regression cannot silently re-derive its
# own bug.  Codecs outside this table have no derivable per-hop error
# bound (core/codec.py tolerance() model) and SV008 refuses them.
CODEC_WIRE = {
    "bf16": (2, False),
    "int8": (1, True),
    "fp8_e4m3": (1, True),
}

# Only algorithms whose hops are explicit ppermutes may carry a codec:
# every hop is a dequantize-reduce-requantize boundary, and psum /
# ps_gather hide their hop structure inside the vendor collective.
CODEC_ALGORITHMS = ("ring_rsa", "rhd_rsa")

# One float32 scale scalar rides each hop of a scaled codec.
CODEC_SCALE_BYTES = 4


def codec_tolerance(sched) -> float | None:
    """Worst-bucket relative error bound of the schedule's wire codecs:
    per codec'd stage, the per-hop model ``hops·eps`` (``·p`` for int8
    absmax growth) of :func:`repro.core.codec.tolerance`, summed over a
    bucket's stages, maxed over buckets.  Hops are ``allreduce_steps``
    for allreduce stages and ``d−1`` for each RS/AG stage.  Returns 0.0
    when nothing is codec'd and ``None`` when any stage carries a codec
    with no derivable bound (the condition SV008 reports)."""
    from repro.core import codec as codec_mod
    worst = 0.0
    for b in sched.buckets:
        acc = 0.0
        for st in b.stages:
            cname = getattr(st, "codec", "none")
            if cname == "none":
                continue
            if st.op == "allreduce":
                try:
                    hops = reducers.allreduce_steps(st.algorithm,
                                                    st.axis_size)
                except ValueError:
                    return None
            else:
                hops = st.axis_size - 1
            bound = codec_mod.tolerance(cname, st.axis_size, hops=hops)
            if bound is None:
                return None
            acc += bound
        worst = max(worst, acc)
    return worst


# ---------------------------------------------------------------------------
# closed forms (SV001)
# ---------------------------------------------------------------------------

def closed_form_wire_bytes(strategy: str, n_bytes: int,
                           axis_sizes: tuple[int, ...]) -> int:
    """Total per-device wire bytes the reducers charge for one
    allreduce of ``n_bytes`` — the independent arithmetic SV001 holds
    every bucket's stage sum against."""
    parts = schedule_mod.split_strategy(strategy)
    if len(parts) == 1:
        return reducers.wire_bytes(parts[0], n_bytes, axis_sizes)
    inner, outer = parts
    pods, d = axis_sizes
    if (inner, outer) == ("ring_rsa", "rhd_rsa"):
        levels = reducers.hierarchical_wire_bytes(n_bytes, d=d, pods=pods)
        return levels["intra"] + levels["inter"]
    intra = 0 if d == 1 else 2 * int(n_bytes * (d - 1) / d)
    return intra + reducers.wire_bytes(outer, n_bytes // d, pods)


# ---------------------------------------------------------------------------
# per-rule checkers
# ---------------------------------------------------------------------------

def _rule_sv000(sched, out):
    ok = True

    def err(loc, msg):
        nonlocal ok
        ok = False
        out.append(Diagnostic("SV000", ERROR, loc, msg))

    names, sizes = sched.axis_names, sched.axis_sizes
    if len(names) != len(sizes) or not names:
        err("", f"axis names {names} / sizes {sizes} mismatch")
    if len(set(names)) != len(names):
        err("", f"duplicate mesh axis names {names}")
    for ax, s in zip(names, sizes):
        if int(s) < 1:
            err("", f"axis {ax!r} has non-positive size {s}")
    if sched.placement not in schedule_mod.PLACEMENTS:
        err("", f"placement {sched.placement!r} not in "
                f"{schedule_mod.PLACEMENTS}")
    try:
        jnp.dtype(sched.wire_dtype)
    except TypeError:
        err("", f"unparseable wire dtype {sched.wire_dtype!r}")
    seen_idx = set()
    for b in sched.buckets:
        if b.index in seen_idx:
            err(b.path, f"duplicate bucket index {b.index}")
        seen_idx.add(b.index)
        try:
            parts = schedule_mod.split_strategy(b.strategy)
            if len(parts) == 2 and len(names) != 2:
                err(b.path, f"composed strategy {b.strategy!r} on a "
                            f"{len(names)}-axis mesh")
        except ValueError as e:
            err(b.path, str(e))
        if b.n_bytes < 0 or b.size < 0:
            err(b.path, f"negative size/bytes ({b.size}/{b.n_bytes})")
    return ok


def _decomposable(sched, bucket) -> bool:
    """Can decompose() resolve this bucket on this mesh?  (SV000 has
    already reported the failure; byte rules skip such buckets.)"""
    try:
        parts = schedule_mod.split_strategy(bucket.strategy)
    except ValueError:
        return False
    return not (len(parts) == 2 and len(sched.axis_names) != 2)


_STAGE_FIELDS = ("op", "algorithm", "axis", "axis_size", "n_bytes",
                 "wire_bytes")


def _bracketed(sched, bucket) -> bool:
    """Does this bucket carry the model bracket (DESIGN.md §3.12)?
    The opener is structural: a bracketed stage list starts with the
    zero-wire ``shard`` op on the schedule's model axis."""
    return (sched.model_axis is not None and sched.model_axis_size > 1
            and bool(bucket.stages) and bucket.stages[0].op == "shard")


def _rule_sv001(sched, out):
    for b in sched.buckets:
        if not _decomposable(sched, b):
            continue
        if _bracketed(sched, b):
            # Re-derive the whole bracket: decompose() itself emits the
            # shard opener, the chunk-sized dp stages, and the terminal
            # model all_gather, so the fresh list is an end-to-end
            # independent derivation of the three-level composition.
            fresh = schedule_mod.decompose(
                b.strategy, b.n_bytes,
                sched.axis_names, sched.axis_sizes,
                wire_itemsize=int(jnp.dtype(sched.wire_dtype).itemsize),
                model_axis=sched.model_axis,
                model_axis_size=sched.model_axis_size)
        else:
            fresh = schedule_mod.decompose(b.strategy, b.n_bytes,
                                           sched.axis_names,
                                           sched.axis_sizes)
        if len(fresh) != len(b.stages):
            out.append(Diagnostic(
                "SV001", ERROR, b.path,
                f"strategy {b.strategy!r} decomposes into {len(fresh)} "
                f"stage(s) on mesh {sched.axis_sizes}, schedule carries "
                f"{len(b.stages)}"))
            continue
        for j, (st, want) in enumerate(zip(b.stages, fresh)):
            coded = getattr(st, "codec", "none") != "none"
            for f in _STAGE_FIELDS:
                if coded and f == "wire_bytes":
                    continue         # encoded accounting: SV008 owns it
                got_v, want_v = getattr(st, f), getattr(want, f)
                if got_v != want_v:
                    out.append(Diagnostic(
                        "SV001", ERROR, b.stage_path(j),
                        f"stage {f}={got_v!r} but "
                        f"{b.strategy!r}@{b.n_bytes}B over "
                        f"{sched.axis_sizes} requires {want_v!r}"))
        if any(getattr(st, "codec", "none") != "none"
               for st in b.stages):
            continue                 # coded buckets: SV008 re-derives
        total = sum(st.wire_bytes for st in b.stages)
        if _bracketed(sched, b):
            # Bracket closed form: the dp levels move the per-model-rank
            # chunk, plus (m-1)/m of the chunked payload for the
            # terminal model all_gather (ring AG of m chunks).
            m = sched.model_axis_size
            chunk = schedule_mod.bracket_chunk_bytes(
                b.n_bytes, m, int(jnp.dtype(sched.wire_dtype).itemsize))
            want_total = closed_form_wire_bytes(
                b.strategy, chunk, sched.axis_sizes) + (m - 1) * chunk
        else:
            want_total = closed_form_wire_bytes(b.strategy, b.n_bytes,
                                                sched.axis_sizes)
        if total != want_total:
            out.append(Diagnostic(
                "SV001", ERROR, b.path,
                f"bucket wire bytes {total} != closed form "
                f"{want_total} ({b.strategy!r}, {b.n_bytes}B, "
                f"mesh {sched.axis_sizes})"))


def _rule_sv002(sched, out):
    mesh = dict(zip(sched.axis_names, sched.axis_sizes))
    if sched.model_axis is not None and sched.model_axis_size > 1:
        # The manual tensor-parallel axis is schedule metadata, not a dp
        # axis: its shard/all_gather bracket obeys the same stack
        # discipline but is excluded from reduce coverage (nothing is
        # ever summed over it).
        mesh[sched.model_axis] = sched.model_axis_size
    for b in sched.buckets:
        stack: list[str] = []
        covered: dict[str, int] = {ax: 0 for ax in sched.axis_names}
        broken = False
        for j, st in enumerate(b.stages):
            loc = b.stage_path(j)
            if st.axis not in mesh:
                out.append(Diagnostic(
                    "SV002", ERROR, loc,
                    f"stage axis {st.axis!r} is not a mesh axis "
                    f"{sched.axis_names}"))
                broken = True
                continue
            if st.axis_size != mesh[st.axis]:
                out.append(Diagnostic(
                    "SV002", ERROR, loc,
                    f"stage axis_size {st.axis_size} != mesh size "
                    f"{mesh[st.axis]} of axis {st.axis!r}"))
            if st.op == "shard":
                # Bracket opener: pushes like reduce_scatter (the
                # terminal model all_gather pops it) but reduces
                # nothing, so it never counts toward coverage.
                stack.append(st.axis)
            elif st.op == "reduce_scatter":
                stack.append(st.axis)
                covered[st.axis] += 1
            elif st.op == "all_gather":
                if not stack or stack[-1] != st.axis:
                    out.append(Diagnostic(
                        "SV002", ERROR, loc,
                        f"all_gather@{st.axis} without a matching open "
                        f"reduce_scatter (pending {stack})"))
                    broken = True
                else:
                    stack.pop()
            elif st.op == "allreduce":
                covered[st.axis] += 1
            else:
                out.append(Diagnostic(
                    "SV002", ERROR, loc, f"unknown stage op {st.op!r}"))
                broken = True
        if stack:
            out.append(Diagnostic(
                "SV002", ERROR, b.path,
                f"unterminated reduce_scatter stage(s) on axes {stack}"))
            broken = True
        if broken or not b.stages:
            continue
        for ax, n in covered.items():
            if n != 1 and not (mesh[ax] == 1 and n == 0):
                out.append(Diagnostic(
                    "SV002", ERROR, b.path,
                    f"mesh axis {ax!r} (size {mesh[ax]}) reduced "
                    f"{n} time(s); must be exactly once"))


def _rule_sv003(sched, out):
    indexed = [b for b in sched.buckets if b.leaf_indices]
    if not indexed:
        return                       # fully detached: no layout to tile
    seen: dict[int, str] = {}
    for b in indexed:
        for i in b.leaf_indices:
            if i in seen:
                out.append(Diagnostic(
                    "SV003", ERROR, b.path,
                    f"leaf {i} already owned by {seen[i]} (overlap)"))
            seen[i] = b.path
    n_leaves = len(sched.plan.leaves) if sched.plan is not None \
        else max(seen) + 1
    missing = sorted(set(range(n_leaves)) - set(seen))
    if missing:
        head = ", ".join(str(i) for i in missing[:8])
        out.append(Diagnostic(
            "SV003", ERROR, "",
            f"{len(missing)} of {n_leaves} gradient leaves are in no "
            f"bucket (gap at {head}{'…' if len(missing) > 8 else ''})"))
    extra = sorted(i for i in seen if i >= n_leaves)
    if extra:
        out.append(Diagnostic(
            "SV003", ERROR, "",
            f"leaf indices {extra[:8]} exceed the gradient tree "
            f"({n_leaves} leaves)"))


def _rule_sv004(sched, out):
    n = len(sched.buckets)
    ranks = sorted(b.readiness_rank for b in sched.buckets)
    if ranks != list(range(n)):
        out.append(Diagnostic(
            "SV004", ERROR, "",
            f"readiness ranks {ranks} are not a permutation of "
            f"0..{n - 1}"))
        return
    if not all(b.leaf_indices for b in sched.buckets):
        return                       # detached: no layout to order by
    by_rank = sorted(sched.buckets, key=lambda b: b.readiness_rank)
    prev = None
    for b in by_rank:
        lo = min(b.leaf_indices)
        if prev is not None and lo >= prev[0]:
            out.append(Diagnostic(
                "SV004", ERROR, b.path,
                f"rank {b.readiness_rank} has min leaf {lo} >= "
                f"{prev[0]} of rank-{prev[1].readiness_rank} "
                f"{prev[1].path}: issue order is not reverse-layer "
                f"(backward produces high-index leaves' grads first)"))
        prev = (lo, b)


def _rule_sv005(sched, out):
    if sched.plan is None or not sched.switch_points:
        return
    itemsize = jnp.dtype(sched.wire_dtype).itemsize
    leaves = sched.plan.leaves
    for b in sched.buckets:
        if len(b.leaf_indices) < 2:
            continue                 # single leaves may span freely
        acc = 0
        for i in b.leaf_indices:
            nb = leaves[i].size * itemsize
            if acc:                  # first leaf opens the bucket
                for s in sched.switch_points:
                    if acc < s < acc + nb:
                        out.append(Diagnostic(
                            "SV005", ERROR, b.path,
                            f"fused bucket grows past the selector "
                            f"crossover at {s}B while appending leaf "
                            f"{i} ({acc}B -> {acc + nb}B): the bucket "
                            f"spans two algorithm regimes"))
            acc += nb


def _rule_sv006(sched, out):
    if not sched.buckets:
        return
    if wire_tolerance(sched) is None:
        out.append(Diagnostic(
            "SV006", ERROR, "",
            f"wire dtype {sched.wire_dtype!r} has no derivable "
            f"summation-tolerance bound (WIRE_EPS covers "
            f"{sorted(WIRE_EPS)})"))


def _perturb_latencies(sched):
    """The same schedule with every predicted latency shifted — what
    a cost-model constant bump does to a re-plan."""
    buckets = tuple(
        dataclasses.replace(
            b, predicted_s=b.predicted_s + 1.0,
            stages=tuple(dataclasses.replace(st,
                                             predicted_s=st.predicted_s
                                             + 1.0)
                         for st in b.stages))
        for b in sched.buckets)
    return dataclasses.replace(sched, buckets=buckets)


def _rule_sv007(sched, out):
    shifted = _perturb_latencies(sched)
    for detached in (False, True):
        if sched.fingerprint(detached=detached) \
                != shifted.fingerprint(detached=detached):
            out.append(Diagnostic(
                "SV007", ERROR, "",
                f"fingerprint(detached={detached}) moves when predicted "
                f"latencies change: re-planning under updated cost-model "
                f"constants would fault the plan cache / trajectory "
                f"diff"))


def _coded_stage_wire_bytes(st, bucket_bytes: int, wire_itemsize: int,
                            itemsize: int, scaled: bool) -> int:
    """Independent re-derivation of one codec'd stage's wire bytes.

    Quantization happens in decoded elements: a stage moving N decoded
    bytes of a ``wire_itemsize``-byte dtype holds ``N // wire_itemsize``
    elements, each ``itemsize`` bytes on the wire once encoded.  The
    algorithmic fraction of those encoded bytes then follows the same
    closed forms SV001 holds uncoded stages to, plus one f32 scale
    scalar per hop for scaled codecs (the per-bucket absmax rides every
    ppermute alongside its payload).

    RS/AG stages are charged from the BUCKET's total bytes (an inner
    ring level moves ``enc·(d−1)/d`` whether scattering or gathering —
    the AG stage's own ``n_bytes`` is the already-divided chunk and
    cannot reproduce decompose's flooring exactly).
    """
    if st.op == "allreduce":
        enc = (st.n_bytes // wire_itemsize) * itemsize
        p = st.axis_size
        if st.algorithm == "ring_rsa":
            wire = int(2 * enc * (p - 1) / p)
            hops = 2 * (p - 1)
        else:                        # rhd_rsa (legality checked first)
            core = 1 << (p.bit_length() - 1)
            wire = int(2 * enc * (core - 1) / core)
            hops = 2 * core.bit_length() - 2
            if core != p:            # MVAPICH2 pre/post fold
                wire += 2 * enc
                hops += 2
        return wire + (hops * CODEC_SCALE_BYTES if scaled else 0)
    # reduce_scatter / all_gather: one ring level of d−1 hops
    d = st.axis_size
    enc = (bucket_bytes // wire_itemsize) * itemsize
    wire = int(enc * (d - 1) / d)
    return wire + ((d - 1) * CODEC_SCALE_BYTES if scaled else 0)


def _rule_sv008(sched, out):
    try:
        wire_itemsize = int(jnp.dtype(sched.wire_dtype).itemsize)
    except TypeError:
        return                       # SV000 already reported the dtype
    for b in sched.buckets:
        for j, st in enumerate(b.stages):
            cname = getattr(st, "codec", "none")
            if cname == "none":
                continue
            loc = b.stage_path(j)
            spec = CODEC_WIRE.get(cname)
            if spec is None:
                out.append(Diagnostic(
                    "SV008", ERROR, loc,
                    f"wire codec {cname!r} has no derivable per-hop "
                    f"error bound (CODEC_WIRE covers "
                    f"{sorted(CODEC_WIRE)})"))
                continue
            if st.algorithm not in CODEC_ALGORITHMS:
                out.append(Diagnostic(
                    "SV008", ERROR, loc,
                    f"codec {cname!r} on algorithm {st.algorithm!r}: "
                    f"only {CODEC_ALGORITHMS} expose per-hop ppermutes "
                    f"to re-quantize at"))
                continue
            itemsize, scaled = spec
            want = _coded_stage_wire_bytes(st, b.n_bytes, wire_itemsize,
                                           itemsize, scaled)
            if st.wire_bytes != want:
                out.append(Diagnostic(
                    "SV008", ERROR, loc,
                    f"codec'd stage wire bytes {st.wire_bytes} != "
                    f"{want} (codec {cname!r}: "
                    f"{st.n_bytes}B decoded / {wire_itemsize}B elems "
                    f"→ {itemsize}B on the wire"
                    f"{' + 4B scale per hop' if scaled else ''})"))


# Fused-hop legality for SV009 — RESTATED independently of
# ``reducers.FUSED_HOP_ALGORITHMS`` (same policy as CODEC_WIRE: the
# verifier's tables must not be derived from the modules it audits).
# The Pallas kernel fuses decode → fp32 ACCUMULATE → encode, so only
# stages with an accumulating hop (ring/RHD ppermute folds) or an
# accumulating terminal (ps_gather's sum over the gathered axis) can
# carry it.  all_gather/shard move bytes without accumulating and psum
# hides its hops inside the vendor collective — a fused flag there
# names an execution route that does not exist.
FUSED_HOP_OPS = {
    "allreduce": ("ring_rsa", "rhd_rsa", "ps_gather"),
    "reduce_scatter": ("ring_rsa",),
}


def _rule_sv009(sched, out):
    fused_any = False
    for b in sched.buckets:
        for j, st in enumerate(b.stages):
            if not getattr(st, "fused_hop", False):
                continue
            fused_any = True
            loc = b.stage_path(j)
            legal = FUSED_HOP_OPS.get(st.op, ())
            if st.algorithm not in legal:
                out.append(Diagnostic(
                    "SV009", ERROR, loc,
                    f"fused_hop on {st.op}/{st.algorithm}: the fused "
                    f"kernel needs an accumulating hop or terminal "
                    f"reduce (legal: "
                    f"{ {k: v for k, v in FUSED_HOP_OPS.items()} })"))
    if not fused_any:
        return
    # Flag-flip invariance: fusion is an execution ROUTE, not a
    # different reduction — clearing every fused_hop flag must leave
    # the derived error bounds and every stage's byte accounting
    # untouched.  A fused schedule whose tolerance or wire bytes moved
    # would mean the kernel changed the arithmetic contract the static
    # walls certify.
    unfused = schedule_mod.with_fused_hops(sched, False)
    if codec_tolerance(sched) != codec_tolerance(unfused):
        out.append(Diagnostic(
            "SV009", ERROR, "",
            f"codec tolerance moves when fused_hop flags are cleared "
            f"({codec_tolerance(sched)} != "
            f"{codec_tolerance(unfused)}): fused schedules must carry "
            f"the same derived bound as unfused"))
    if wire_tolerance(sched) != wire_tolerance(unfused):
        out.append(Diagnostic(
            "SV009", ERROR, "",
            "wire tolerance moves when fused_hop flags are cleared"))
    for b, ub in zip(sched.buckets, unfused.buckets):
        for j, (st, ust) in enumerate(zip(b.stages, ub.stages)):
            if (st.wire_bytes, st.n_bytes) != (ust.wire_bytes,
                                               ust.n_bytes):
                out.append(Diagnostic(
                    "SV009", ERROR, b.stage_path(j),
                    f"stage bytes change under the fused_hop flag flip "
                    f"(wire {st.wire_bytes} vs {ust.wire_bytes}, "
                    f"decoded {st.n_bytes} vs {ust.n_bytes})"))


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def verify_schedule(sched, context: str = "") -> list[Diagnostic]:
    """Run every SV rule over ``sched``; returns all findings (empty =
    the schedule is statically sound)."""
    out: list[Diagnostic] = []
    _rule_sv000(sched, out)
    # byte/stage rules assume parseable strategies; SV000 already
    # reported unparseable ones and _decomposable skips those buckets
    _rule_sv001(sched, out)
    _rule_sv002(sched, out)
    _rule_sv003(sched, out)
    _rule_sv004(sched, out)
    _rule_sv005(sched, out)
    _rule_sv006(sched, out)
    _rule_sv007(sched, out)
    _rule_sv008(sched, out)
    _rule_sv009(sched, out)
    if context:
        out = [dataclasses.replace(d, context=context) for d in out]
    return out


def verify_summary(sched, context: str = "") -> dict:
    """verify + the record shape dryrun embeds (repro/analysis/v1)."""
    from . import summarize
    diags = verify_schedule(sched, context=context)
    return summarize(diags, extra={
        "fingerprint": sched.fingerprint(),
        "n_buckets": sched.n_buckets,
        "decomposition": sched.render(),
        "axis_sizes": list(sched.axis_sizes),
        "wire_tolerance": wire_tolerance(sched),
        "codec_tolerance": codec_tolerance(sched),
    })
