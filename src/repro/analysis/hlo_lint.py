"""Collective linter over compiled HLO text — rules ``HL0xx``.

The generalization of ``launch/roofline.wire_check`` (one hand-rolled
byte comparison) into a multi-rule pass driven by the same
ReduceSchedule IR.  :func:`wire_check` here IS the old function, moved
verbatim — ``roofline.wire_check`` is now a thin wrapper over it, so
every dryrun/report/sweep record is byte-identical — and HL001 turns
its verdict into typed diagnostics alongside three new rules:

``HL001``  per-kind charged collective bytes must cover the IR's
           per-stage ``hlo_bytes`` prediction (the wire check).
``HL002``  ``placement="in_backward"`` must actually interleave: at
           least one full bucket's collective-permutes issue before
           the last backward dot (tests/test_overlap_hlo.py's
           ``perm_vs_dots`` discipline as a lint rule).
``HL003``  no mixed-dtype reduction ops: every all-reduce /
           reduce-scatter must carry one element dtype across its
           operands and results (a silent upcast on the wire
           invalidates the wire-dtype byte accounting).
``HL004``  *warn*: charged all-reduce bytes where the schedule
           predicts a pure RSA/permute decomposition (no ``psum``
           stage) — XLA substituted or added a vendor allreduce.
           Legitimate sources exist (model-axis GSPMD collectives),
           hence warn severity + the baseline.
``HL005``  fused-hop codec soundness: a schedule whose codec'd stages
           run the fused Pallas hop kernel must keep its f32-typed
           collective-permute traffic within the budget of the
           legitimately-f32 payloads (uncoded permute stages) plus one
           4-byte scale scalar per fused coded hop.  An f32 permute
           carrying a full coded payload means XLA's convert-mover
           floated the decode outside the permute — the wire went back
           to 4 bytes/element and the codec's bandwidth win silently
           vanished (the bitcast pinning of ``core/codec.py`` exists
           to prevent exactly this).

Warning baseline: ``ANALYSIS_BASELINE.json`` (schema
``repro/analysis-baseline/v1``) at the repo root lists accepted
warnings as ``{"rule_id": ..., "context": ...}`` entries (``"*"``
context matches everywhere).  ``--check-baseline`` fails the CLI on
any warning NOT in the baseline — errors are never baselinable.
Inline suppression: a line ``analysis-suppress: HL003[, HL004]``
anywhere in the linted text disables those rules for that text.
"""
from __future__ import annotations

import json
import os
import re

from repro.core import reducers

from . import ERROR, WARN, Diagnostic

RULES = {
    "HL001": "charged collective bytes cover the IR per-stage bytes",
    "HL002": "in_backward schedules interleave >=1 bucket before the "
             "last backward dot",
    "HL003": "no mixed-dtype reduction ops",
    "HL004": "no unexpected all-reduce under an RSA decomposition "
             "(warn)",
    "HL005": "fused codec'd schedules keep f32 permute traffic within "
             "the scale-scalar budget (no free-floating converts)",
}

BASELINE_SCHEMA = "repro/analysis-baseline/v1"
BASELINE_FILE = "ANALYSIS_BASELINE.json"

_SUPPRESS_RE = re.compile(r"analysis-suppress:\s*([A-Z0-9, ]+)")
_REDUCTION_RE = re.compile(r"\b(all-reduce|reduce-scatter)(?:-start)?\(")
_DTYPE_RE = re.compile(
    r"\b(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|"
    r"f8e4m3fn|f8e5m2|s4|u4)\[")


# ---------------------------------------------------------------------------
# wire_check — moved verbatim from launch/roofline.py (which now wraps
# this; the dict it returns is pinned by tests/test_claims.py)
# ---------------------------------------------------------------------------

def wire_check(sched, collective_bytes, rel_tol: float = 0.02) -> dict:
    """Measured-vs-modeled comm-byte consistency (DESIGN.md §3.7/§4):
    compare the HLO-charged collective bytes of a compiled step against
    the per-STAGE wire bytes carried by the resolved
    :class:`repro.core.schedule.ReduceSchedule` — no independent
    re-derivation: the IR the aggregator executed is the same object
    being verified.

    ``sched``: a ReduceSchedule (attached or detached/deserialized).
    ``collective_bytes``: the per-kind byte dict from the HLO parse.
    Each stage predicts the HLO kind it compiles to (``Stage.hlo_kind``:
    ppermute schedules → collective-permute, ``psum`` → all-reduce
    payload, ``ps_gather`` → all-gather) and the bytes it charges
    (``Stage.hlo_bytes``).  The charged side may legitimately exceed
    the prediction (model-axis GSPMD collectives, padding on
    non-divisible chunks, old-jax degraded-mode emulation), so the
    verdict is per kind: ``consistent`` = every predicted kind is
    within ``rel_tol`` below the charge it explains or lower.
    """
    predicted: dict = {}
    for bucket in sched.buckets:
        for st in bucket.stages:
            if st.hlo_kind is None:
                continue             # "shard" bracket opener: local
            predicted[st.hlo_kind] = predicted.get(st.hlo_kind, 0) \
                + st.hlo_bytes
    charged = {k: int(v) for k, v in collective_bytes.items()}
    kinds = {}
    for kind, want in sorted(predicted.items()):
        got = charged.get(kind, 0)
        kinds[kind] = {
            "predicted": int(want), "charged": got,
            "ratio": (got / want) if want else None,
            # charged >= predicted*(1-tol): the schedule's bytes are in
            # the HLO (extra charge from other collectives is allowed)
            "ok": got >= want * (1.0 - rel_tol),
        }
    return {
        "axis_sizes": list(sched.axis_sizes),
        "predicted_total": int(sum(predicted.values())),
        "charged_total": int(sum(charged.values())),
        "kinds": kinds,
        "consistent": all(k["ok"] for k in kinds.values()),
    }


# ---------------------------------------------------------------------------
# per-stage permute accounting (HL002)
# ---------------------------------------------------------------------------

def stage_permute_steps(stage) -> int:
    """collective-permute ops one stage compiles to (0 for stages that
    lower to vendor all-reduce / all-gather)."""
    if stage.hlo_kind != "collective-permute":
        return 0
    if stage.op == "allreduce":
        return reducers.allreduce_steps(stage.algorithm, stage.axis_size)
    # one ring pass: reduce_scatter and all_gather each take p-1 hops
    return max(stage.axis_size - 1, 0)


def min_bucket_permute_steps(sched) -> int:
    """Permute count of the cheapest full bucket — the least HL002 can
    demand before the last backward dot (0 when no bucket permutes)."""
    counts = [sum(stage_permute_steps(st) for st in b.stages)
              for b in sched.buckets]
    counts = [c for c in counts if c > 0]
    return min(counts) if counts else 0


_F32_SHAPE = re.compile(r"\bf32\[([\d,]*)\]")


def f32_permute_bytes(hlo_text: str) -> int:
    """f32 payload bytes moved by collective-permute instructions — the
    measured side of HL005.  Per permute line the LARGEST single f32
    shape token counts (a ``-start``'s tuple type lists the aliased
    input and output once each; the payload must not be double-charged),
    summed over every permute in the text."""
    total = 0
    for line in hlo_text.splitlines():
        # Split at the OP token (with its paren) — the instruction's
        # own %collective-permute.N name appears first on the line and
        # must not truncate the head before the result type.
        for marker in ("collective-permute-start(",
                       "collective-permute("):
            if marker in line:
                head = line.split(marker, 1)[0]
                break
        else:
            continue
        best = 0
        for m in _F32_SHAPE.finditer(head):
            n = 1
            for d in m.group(1).split(","):
                if d:
                    n *= int(d)
            best = max(best, n * 4)
        total += best
    return total


def fused_f32_permute_budget(sched) -> int:
    """Upper bound on LEGITIMATE f32 permute bytes of a fused codec'd
    schedule: uncoded (or unfused) permute stages move their full
    payload in f32, and each fused coded hop carries exactly one
    4-byte f32 absmax scalar next to its bit-pinned payload."""
    budget = 0
    for b in sched.buckets:
        for st in b.stages:
            if st.hlo_kind != "collective-permute":
                continue
            coded = (getattr(st, "codec", "none") or "none") != "none"
            if coded and getattr(st, "fused_hop", False):
                budget += stage_permute_steps(st) * 4
            else:
                budget += st.hlo_bytes
    return budget


def perm_vs_dots(hlo_text: str) -> tuple[int, int]:
    """(permutes before the last dot, total permutes) — the overlap
    witness of tests/test_overlap_hlo.py."""
    lines = hlo_text.splitlines()
    perms = [i for i, l in enumerate(lines) if "collective-permute(" in l]
    dots = [i for i, l in enumerate(lines) if " dot(" in l]
    if not dots:
        return 0, len(perms)
    return sum(1 for i in perms if i < dots[-1]), len(perms)


# ---------------------------------------------------------------------------
# the lint pass
# ---------------------------------------------------------------------------

def _suppressed(hlo_text: str) -> set[str]:
    out: set[str] = set()
    for m in _SUPPRESS_RE.finditer(hlo_text):
        out.update(t.strip() for t in m.group(1).split(",") if t.strip())
    return out


def lint_hlo(sched, hlo_text: str | None = None,
             collective_bytes=None, rel_tol: float = 0.02,
             context: str = "") -> list[Diagnostic]:
    """Run every HL rule.  ``hlo_text`` drives HL002/HL003 (and, via
    the loop-corrected parser, HL001/HL004 when ``collective_bytes``
    is not given); a pre-parsed per-kind byte dict may be passed
    instead when only the byte rules are wanted."""
    out: list[Diagnostic] = []
    skip = _suppressed(hlo_text) if hlo_text else set()
    if collective_bytes is None and hlo_text is not None:
        from repro.launch import hlo_analysis
        collective_bytes = hlo_analysis.analyze(hlo_text).collective_bytes

    if collective_bytes is not None and "HL001" not in skip:
        wc = wire_check(sched, collective_bytes, rel_tol=rel_tol)
        for kind, k in wc["kinds"].items():
            if not k["ok"]:
                out.append(Diagnostic(
                    "HL001", ERROR, kind,
                    f"HLO charges {k['charged']}B of {kind} but the "
                    f"schedule's stages predict {k['predicted']}B "
                    f"(ratio {k['ratio']:.3f} < 1-{rel_tol})",
                    context=context))

    if hlo_text is not None and "HL002" not in skip \
            and sched.placement == "in_backward":
        need = min_bucket_permute_steps(sched)
        before, total = perm_vs_dots(hlo_text)
        if need > 0 and before < need:
            out.append(Diagnostic(
                "HL002", ERROR, "",
                f"placement='in_backward' but only {before} of {total} "
                f"collective-permutes issue before the last backward "
                f"dot (a full bucket needs {need}): the reductions "
                f"serialized into a trailing block", context=context))

    if hlo_text is not None and "HL003" not in skip:
        for ln, line in enumerate(hlo_text.splitlines(), 1):
            if not _REDUCTION_RE.search(line):
                continue
            dtypes = set(_DTYPE_RE.findall(line.split("metadata=")[0]))
            if len(dtypes) > 1:
                out.append(Diagnostic(
                    "HL003", ERROR, f"hlo:{ln}",
                    f"mixed-dtype reduction op ({'/'.join(sorted(dtypes))})"
                    f": wire-dtype byte accounting no longer holds",
                    context=context))

    if hlo_text is not None and "HL005" not in skip:
        fused_coded = any(
            getattr(st, "fused_hop", False)
            and (getattr(st, "codec", "none") or "none") != "none"
            for b in sched.buckets for st in b.stages)
        if fused_coded:
            got = f32_permute_bytes(hlo_text)
            budget = fused_f32_permute_budget(sched)
            # floor absorbs GSPMD bookkeeping permutes outside the
            # schedule (same spirit as HL004's vendor-collective floor)
            allowed = budget + max(1024, budget // 100)
            if got > allowed:
                out.append(Diagnostic(
                    "HL005", ERROR, "collective-permute",
                    f"fused codec'd schedule moves {got}B of f32 "
                    f"collective-permute payload but only {budget}B are "
                    f"legitimate (uncoded payloads + one 4B scale per "
                    f"fused hop): a convert floated outside a permute "
                    f"and the coded wire decayed to f32",
                    context=context))

    if collective_bytes is not None and "HL004" not in skip:
        expects_ar = any(st.hlo_kind == "all-reduce"
                         for b in sched.buckets for st in b.stages)
        charged_ar = int(collective_bytes.get("all-reduce", 0))
        predicted_total = sum(st.hlo_bytes for b in sched.buckets
                              for st in b.stages)
        floor = max(1024, predicted_total // 100)
        if not expects_ar and charged_ar > floor and sched.buckets:
            out.append(Diagnostic(
                "HL004", WARN, "all-reduce",
                f"schedule decomposes into RSA/permute stages only, "
                f"but the HLO charges {charged_ar}B of vendor "
                f"all-reduce (> {floor}B): XLA substituted or added a "
                f"collective outside the schedule", context=context))
    return out


# ---------------------------------------------------------------------------
# warning baseline
# ---------------------------------------------------------------------------

def load_baseline(path: str | None = None) -> list[dict]:
    """Accepted-warning entries from ``ANALYSIS_BASELINE.json`` (repo
    root by default); [] when the file does not exist."""
    if path is None:
        path = BASELINE_FILE
    if not os.path.exists(path):
        return []
    with open(path) as f:
        rec = json.load(f)
    if rec.get("schema") != BASELINE_SCHEMA:
        raise ValueError(f"baseline schema must be {BASELINE_SCHEMA!r}, "
                         f"got {rec.get('schema')!r}")
    return list(rec.get("warnings", []))


def baselined(diag: Diagnostic, baseline: list[dict]) -> bool:
    """Does an accepted-warning entry cover this diagnostic?  Errors
    are never baselinable."""
    if diag.severity != WARN:
        return False
    for entry in baseline:
        if entry.get("rule_id") != diag.rule_id:
            continue
        ctx = entry.get("context", "*")
        if ctx in ("*", diag.context):
            return True
    return False


def unbaselined_warnings(diags, baseline: list[dict]) -> list[Diagnostic]:
    return [d for d in diags
            if d.severity == WARN and not baselined(d, baseline)]
