"""``python -m repro.analysis`` — the static-verification gate.

Modes (default = ``--source --schedules``):

``--source``          compat-lint the source tree (CL rules).
``--schedules``       verify every registered config × design × mesh
                      cell from ``experiments/matrix.analysis_cells``
                      (SV rules) — including the 512-device and
                      composed two-level schedules the executor cannot
                      run on legacy jax.
``--schedule-json F`` verify one serialized ReduceSchedule
                      (``repro/schedule/v1`` JSON, as written by
                      dryrun records or ``to_json``).
``--check-baseline``  additionally fail on warnings not accepted by
                      ``ANALYSIS_BASELINE.json``.
``--json OUT``        write the full diagnostic summary as JSON.

Exit status: non-zero iff any ``error`` diagnostic fired (or, with
``--check-baseline``, any unbaselined warning).  CI runs
``--source --schedules --check-baseline`` on every push.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import errors, hlo_lint, summarize, warnings as warn_of


def _verify_schedules(diags: list) -> int:
    from repro.core import schedule as schedule_mod  # noqa: F401
    from repro.experiments import matrix

    from . import verify as verify_mod
    n = 0
    for label, sched in matrix.analysis_cells():
        diags.extend(verify_mod.verify_schedule(sched, context=label))
        n += 1
    return n


def _verify_schedule_json(path: str, diags: list) -> None:
    from repro.core import schedule as schedule_mod

    from . import verify as verify_mod
    with open(path) as f:
        rec = json.load(f)
    sched = schedule_mod.from_json(rec)
    diags.extend(verify_mod.verify_schedule(sched, context=path))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--source", action="store_true",
                    help="compat-lint the source tree")
    ap.add_argument("--schedules", action="store_true",
                    help="verify every experiment-matrix schedule cell")
    ap.add_argument("--schedule-json",
                    help="verify one repro/schedule/v1 JSON record")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail on warnings not in ANALYSIS_BASELINE.json")
    ap.add_argument("--baseline", default=None,
                    help=f"baseline path (default ./"
                         f"{hlo_lint.BASELINE_FILE})")
    ap.add_argument("--root", default=".",
                    help="repo root for --source (default .)")
    ap.add_argument("--json", dest="json_out",
                    help="write the diagnostic summary to this path")
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    run_source = args.source
    run_schedules = args.schedules
    if not (run_source or run_schedules or args.schedule_json):
        run_source = run_schedules = True

    diags: list = []
    n_cells = 0
    if run_source:
        from . import compat_lint
        diags.extend(compat_lint.lint_tree(args.root))
    if run_schedules:
        n_cells = _verify_schedules(diags)
    if args.schedule_json:
        _verify_schedule_json(args.schedule_json, diags)

    errs = errors(diags)
    warns = warn_of(diags)
    failing = list(errs)
    if args.check_baseline:
        baseline = hlo_lint.load_baseline(args.baseline)
        failing += hlo_lint.unbaselined_warnings(warns, baseline)

    if not args.quiet:
        for d in diags:
            print(d.render())
        scope = []
        if run_source:
            scope.append("source")
        if run_schedules:
            scope.append(f"{n_cells} schedule cells")
        if args.schedule_json:
            scope.append(args.schedule_json)
        print(f"[analysis] {' + '.join(scope)}: {len(errs)} error(s), "
              f"{len(warns)} warning(s)"
              + (f", {len(failing) - len(errs)} unbaselined"
                 if args.check_baseline else ""))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(summarize(diags, extra={"n_cells": n_cells}), f,
                      indent=1)
    return 1 if failing else 0


if __name__ == "__main__":
    sys.exit(main())
