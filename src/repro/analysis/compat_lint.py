"""AST lint: direct jax version-portability APIs stay in core/compat.

PR 1 exists because ``jax.experimental.shard_map`` / ``maps`` / ``pjit``
and the manual-axis collectives moved or changed semantics across jax
releases; ``core/compat.py`` is the single shim everything else routes
through.  This lint bans re-introducing direct uses anywhere else in
the source tree — the exact class of portability bug the compat layer
was built to end:

``CL001``  import of a banned module (``jax.experimental.shard_map``,
           ``jax.experimental.maps``, ``jax.experimental.pjit``).
``CL002``  use (attribute access or from-import) of a banned name
           (``jax.shard_map``, manual-axis ``jax.lax`` collectives:
           ``ppermute`` / ``psum`` / ``pmean`` / ``all_gather`` /
           ``all_to_all`` / ``axis_index`` / ``axis_size``).

Scope: ``src/repro`` (minus ``core/compat.py`` itself), ``benchmarks``,
``examples``.  Tests are exempt — they intentionally poke jax internals
(e.g. a raw ``lax.psum`` as the vendor reference the reducers are
checked against).  ``jax.experimental.pallas`` (kernels/) is NOT
banned: it is an accelerator API, not a sharding-portability surface.

Suppression: append ``# compat-lint: allow`` to the offending line.
"""
from __future__ import annotations

import ast
import os

from . import ERROR, Diagnostic

RULES = {
    "CL001": "no direct import of jax.experimental.shard_map/maps/pjit",
    "CL002": "no direct use of jax.shard_map / manual-axis jax.lax "
             "collectives outside core/compat.py",
}

BANNED_MODULES = ("jax.experimental.shard_map", "jax.experimental.maps",
                  "jax.experimental.pjit")
BANNED_NAMES = frozenset({
    "jax.shard_map",
    "jax.lax.ppermute", "jax.lax.psum", "jax.lax.pmean",
    "jax.lax.all_gather", "jax.lax.all_to_all",
    "jax.lax.axis_index", "jax.lax.axis_size",
})
ALLOW_MARK = "compat-lint: allow"

SCOPE_DIRS = (os.path.join("src", "repro"), "benchmarks", "examples")
EXEMPT_SUFFIXES = (os.path.join("core", "compat.py"),)


def _banned_module(dotted: str) -> bool:
    return any(dotted == m or dotted.startswith(m + ".")
               for m in BANNED_MODULES)


def _dotted(node) -> str | None:
    """`a.b.c` attribute/name chain as a dotted string, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, src_lines: list[str]):
        self.path = path
        self.lines = src_lines
        self.aliases: dict[str, str] = {}   # local name -> dotted origin
        self.diags: list[Diagnostic] = []

    def _allowed(self, lineno: int) -> bool:
        line = self.lines[lineno - 1] if lineno <= len(self.lines) else ""
        return ALLOW_MARK in line

    def _flag(self, rule: str, lineno: int, msg: str):
        if not self._allowed(lineno):
            self.diags.append(Diagnostic(
                rule, ERROR, f"{self.path}:{lineno}", msg))

    def visit_Import(self, node: ast.Import):
        for alias in node.names:
            if _banned_module(alias.name):
                self._flag("CL001", node.lineno,
                           f"import {alias.name} — route through "
                           f"repro.core.compat")
            # `import jax.lax` binds `jax` (or the asname to the full
            # dotted path); record it so attribute uses resolve
            bound = alias.asname or alias.name.split(".")[0]
            self.aliases[bound] = alias.name if alias.asname \
                else alias.name.split(".")[0]
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        if node.level == 0:          # absolute imports only
            if _banned_module(mod):
                self._flag("CL001", node.lineno,
                           f"from {mod} import ... — route through "
                           f"repro.core.compat")
            for alias in node.names:
                full = f"{mod}.{alias.name}" if mod else alias.name
                if _banned_module(full):
                    self._flag("CL001", node.lineno,
                               f"from {mod} import {alias.name} — route "
                               f"through repro.core.compat")
                elif full in BANNED_NAMES:
                    self._flag("CL002", node.lineno,
                               f"from {mod} import {alias.name} — use "
                               f"repro.core.compat.{alias.name}")
                self.aliases[alias.asname or alias.name] = full
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        dotted = _dotted(node)
        if dotted:
            head, _, rest = dotted.partition(".")
            origin = self.aliases.get(head, head)
            full = f"{origin}.{rest}" if rest else origin
            if full in BANNED_NAMES:
                self._flag("CL002", node.lineno,
                           f"{dotted} resolves to {full} — use "
                           f"repro.core.compat."
                           f"{full.rsplit('.', 1)[1]}")
            elif _banned_module(full):
                self._flag("CL001", node.lineno,
                           f"{dotted} resolves to {full} — route "
                           f"through repro.core.compat")
        self.generic_visit(node)


def lint_file(path: str, rel: str | None = None) -> list[Diagnostic]:
    """Lint one Python file; ``rel`` overrides the location prefix."""
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [Diagnostic("CL000", ERROR, f"{rel or path}:{e.lineno}",
                           f"syntax error: {e.msg}")]
    v = _Visitor(rel or path, src.splitlines())
    v.visit(tree)
    return v.diags


def iter_source_files(root: str):
    """Yield (abs_path, rel_path) of every in-scope .py file."""
    for scope in SCOPE_DIRS:
        base = os.path.join(root, scope)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames
                           if d != "__pycache__" and not d.startswith(".")]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                abs_path = os.path.join(dirpath, fn)
                rel = os.path.relpath(abs_path, root)
                if any(rel.endswith(sfx) for sfx in EXEMPT_SUFFIXES):
                    continue
                yield abs_path, rel


def lint_tree(root: str = ".") -> list[Diagnostic]:
    """Lint every in-scope source file under ``root``."""
    out: list[Diagnostic] = []
    for abs_path, rel in iter_source_files(root):
        out.extend(lint_file(abs_path, rel=rel))
    return out
