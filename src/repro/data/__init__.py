from .synthetic import SyntheticImages, SyntheticText, batch_pspecs

__all__ = ["SyntheticText", "SyntheticImages", "batch_pspecs"]
