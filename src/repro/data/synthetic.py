"""Synthetic data pipeline.

The paper deliberately benchmarks with synthetic inputs (Sec. IV): "To
prevent that our results are influenced by file I/O (disk) performance,
we only use synthetic input data ... we purely measure the GPU and
network performance". We follow the same methodology: deterministic
on-device token/image generation, so every throughput difference is
attributable to the aggregation algorithm.

Text batches model a Zipf-ish unigram stream with a learnable structure
(labels = next token) so small end-to-end trainings show decreasing loss.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelSpec


@dataclasses.dataclass
class SyntheticText:
    """Deterministic synthetic LM batches: a noisy affine token recurrence
    (t_{i+1} = (a * t_i + b + noise) mod V) that a model can learn."""
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.05

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def batch_at(self, step: int) -> dict:
        key = jax.random.PRNGKey(self.seed + step * 9973)
        k1, k2, k3 = jax.random.split(key, 3)
        v = self.vocab_size
        t0 = jax.random.randint(k1, (self.batch, 1), 0, v)
        # affine recurrence expanded in closed form for speed
        i = jnp.arange(self.seq_len + 1)
        b = 17
        toks = (t0 + (i[None, :] * b)) % v
        flip = jax.random.bernoulli(k2, self.noise,
                                    (self.batch, self.seq_len + 1))
        rand = jax.random.randint(k3, (self.batch, self.seq_len + 1), 0, v)
        toks = jnp.where(flip, rand, toks).astype(jnp.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclasses.dataclass
class SyntheticImages:
    """Synthetic image batches for the CNN (tf_cnn_benchmarks analogue)."""
    batch: int
    image_size: int = 224
    num_classes: int = 1000
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        key = jax.random.PRNGKey(self.seed + step)
        k1, k2 = jax.random.split(key)
        images = jax.random.normal(
            k1, (self.batch, self.image_size, self.image_size, 3),
            jnp.float32)
        labels = jax.random.randint(k2, (self.batch,), 0, self.num_classes)
        return {"images": images, "labels": labels}


def extra_inputs(spec: ModelSpec, batch: int, key=None) -> dict:
    """Stub modality-frontend embeddings (audio frames / vision patches)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    out = {}
    if spec.family == "audio":
        out["frames"] = jax.random.normal(
            key, (batch, spec.encoder_seq, spec.d_model), jnp.bfloat16)
    if spec.family == "vlm":
        out["patches"] = jax.random.normal(
            key, (batch, spec.num_image_tokens, spec.d_model), jnp.bfloat16)
    return out


def batch_pspecs(batch_like, dp_axes) -> dict:
    """PartitionSpecs sharding the leading (batch) dim over the data axes."""
    dp = tuple(dp_axes)
    return jax.tree_util.tree_map(
        lambda x: P(dp, *([None] * (x.ndim - 1))), batch_like)
